"""Kernel + engine micro-benchmarks: tuned vs default vs jnp.

Every Pallas kernel family is timed three ways at the same shape:

* ``jnp``     — the reference path (``ref.py``), the number to beat;
* ``default`` — the kernel under its PRE-tuning-era static 128 tiles
  (the PR-7 configuration; for ``assign`` this is the per-arrival
  ``lax.map`` kernel that PR 8 replaced);
* ``tuned``   — the kernel under ``kernels.tuning`` block resolution
  (autotune cache if populated, per-backend heuristics otherwise).

Off-accelerator the kernels execute in interpret mode, where wall time
measures the interpreter's per-grid-step cost — which is exactly what the
CPU heuristics minimize, so the ``gap_shrink`` column (default-gap /
tuned-gap vs jnp) is the honest figure of merit there: it shows how much
of the interpret-mode penalty the tile plan removed.  On TPU/GPU the same
grid runs lowered and ``tuned_vs_jnp`` is the headline.

``--tune`` runs the measured autotune sweep first (populating the cache
that ``REPRO_TUNE_CACHE`` persists); without it the heuristic defaults
are what "tuned" means.  Results land in ``--json``
(``benchmarks/results/bench_kernels.json``).

Also keeps two engine-level rows (streaming blockwise R; fused LPS round)
— whole-protocol numbers the kernel grid feeds into.

Standalone: ``PYTHONPATH=src:. python benchmarks/bench_kernels.py --quick``
(CI smoke: shrunken shapes, same code paths).
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro.core import similarity as sim
from repro.core.engine import ProtocolEngine
from repro.fed import client as fclient
from repro.fed import hierarchy as hier
from repro.kernels import tuning
from repro.kernels.assign import ops as assign_ops
from repro.kernels.assign.ref import assign_ref
from repro.kernels.eigproject import ops as proj_ops
from repro.kernels.eigproject.ref import project_norms_ref
from repro.kernels.featurize_gram import ops as fg_ops
from repro.kernels.featurize_gram.ref import featurize_gram_ref
from repro.kernels.gram import ops as gram_ops
from repro.kernels.gram.ref import gram_ref
from repro.kernels.gram_project import ops as gp_ops
from repro.kernels.gram_project.ref import gram_project_ref
from repro.kernels.linkage import ops as link_ops
from repro.kernels.linkage.ref import linkage_step_ref
from repro.models import mlp

# The pre-tuning-era static tile plans (what every kernel shipped with
# before the autotuner): uniform 128 tiles, no DMA double-buffering.
DEFAULT_BLOCKS = {
    "gram": {"block_n": 128, "block_d": 128},
    "gram_project": {"block_n": 128, "block_k": 128,
                     "double_buffer": False},
    "featurize_gram": {"block_n": 128, "double_buffer": False},
    "eigproject": {"block_d": 128, "block_k": 128},
    "linkage": {"block": 128},
    # pre-tuning chunking for the serving recurrences (bench_serve)
    "recurrent_scan": {"chunk": 16, "block_d": 128},
}


def _grid_candidates(kernel: str, **dims: int) -> list[dict]:
    """A small sweep grid around the heuristic default."""
    heur = tuning.heuristic_blocks(kernel, **dims)
    cands = [dict(heur), {**DEFAULT_BLOCKS.get(kernel, {})} or dict(heur)]
    for scale in (256, 512, 1024, 2048):
        cands.append({k: (min(v, scale) if isinstance(v, int) else v)
                      for k, v in heur.items()})
    seen, out = set(), []
    for c in cands:
        key = tuple(sorted(c.items()))
        if key not in seen:
            seen.add(key)
            out.append(c)
    return out


def _bench_family(name: str, shape_tag: str, ref_fn, pallas_fn, dims: dict,
                  tune: bool, records: list, n_iter: int = 5,
                  assert_shrink: float | None = None) -> str:
    """Time jnp vs default-tiles vs tuned-tiles; validate; record."""
    ref_out = np.asarray(jax.block_until_ready(ref_fn()))
    ref_us = common.time_us(lambda: jax.block_until_ready(ref_fn()),
                            n_iter=n_iter)

    def timed(blocks) -> tuple[float, bool]:
        out = np.asarray(jax.block_until_ready(pallas_fn(blocks)))
        ok = bool(np.allclose(out, ref_out, rtol=1e-3, atol=1e-2))
        us = common.time_us(
            lambda: jax.block_until_ready(pallas_fn(blocks)), n_iter=n_iter)
        return us, ok

    if tune:
        tuning.autotune(
            name, lambda blk: jax.block_until_ready(pallas_fn(blk)),
            _grid_candidates(name, **dims), **dims)
    default_us, default_ok = timed(DEFAULT_BLOCKS[name])
    tuned_blocks = tuning.get_blocks(name, **dims)
    tuned_us, tuned_ok = timed(tuned_blocks)

    gap_default = default_us / ref_us
    gap_tuned = tuned_us / ref_us
    shrink = gap_default / gap_tuned if gap_tuned else float("inf")
    if assert_shrink is not None:
        assert shrink >= assert_shrink, (
            f"{name}: tuned tiles shrank the vs-jnp gap only "
            f"{shrink:.1f}x (< {assert_shrink}x) at {shape_tag}")
    records.append({
        "kernel": name, "shape": shape_tag, "dims": dims,
        "jnp_us": round(ref_us, 1),
        "default_us": round(default_us, 1),
        "tuned_us": round(tuned_us, 1),
        "tuned_blocks": {k: v for k, v in tuned_blocks.items()},
        "gap_default_vs_jnp": round(gap_default, 2),
        "gap_tuned_vs_jnp": round(gap_tuned, 2),
        "gap_shrink": round(shrink, 2),
        "validates": bool(default_ok and tuned_ok),
        "tuned": tune,
    })
    return common.row(
        f"kernel_{name}_{shape_tag}", tuned_us,
        jnp_us=round(ref_us, 1), default_us=round(default_us, 1),
        gap_tuned_vs_jnp=round(gap_tuned, 2),
        gap_shrink_vs_default=round(shrink, 2),
        validates=bool(default_ok and tuned_ok))


def _bench_gram(rng, quick, tune, records):
    n, d = (512, 128) if quick else (4096, 256)
    x = jnp.asarray(rng.standard_normal((n, d)), jnp.float32)
    return _bench_family(
        "gram", f"{n}x{d}", lambda: gram_ref(x),
        lambda blk: gram_ops.gram_matrix(x, block_n=blk["block_n"],
                                         block_d=blk["block_d"]),
        dict(n=n, d=d), tune, records)


def _bench_gram_project(rng, quick, tune, records):
    n, d, k = (512, 128, 128) if quick else (4096, 256, 256)
    x = jnp.asarray(rng.standard_normal((n, d)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((d, k)), jnp.float32)
    return _bench_family(
        "gram_project", f"{n}x{d}x{k}", lambda: gram_project_ref(x, v),
        lambda blk: gp_ops.gram_project(
            x, v, block_n=blk["block_n"], block_k=blk["block_k"],
            double_buffer=blk.get("double_buffer", False)),
        dict(n=n, k=k), tune, records,
        assert_shrink=None if quick else 5.0)


def _bench_featurize_gram(rng, quick, tune, records):
    n, m, d = (512, 256, 128) if quick else (4096, 512, 256)
    x = jnp.asarray(rng.standard_normal((n, m)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((m, d)) / np.sqrt(m), jnp.float32)
    return _bench_family(
        "featurize_gram", f"{n}x{m}x{d}",
        lambda: featurize_gram_ref(x, w),
        lambda blk: fg_ops.featurize_gram(
            x, w, block_n=blk["block_n"],
            double_buffer=blk.get("double_buffer", False)),
        dict(n=n), tune, records)


def _bench_eigproject(rng, quick, tune, records):
    d, k = (128, 64) if quick else (512, 256)
    g = jnp.asarray(rng.standard_normal((d, d)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((d, k)), jnp.float32)
    return _bench_family(
        "eigproject", f"{d}x{k}", lambda: project_norms_ref(g, v),
        lambda blk: proj_ops.project_norms(g, v, block_d=blk["block_d"],
                                           block_k=blk["block_k"]),
        dict(d=d, k=k), tune, records)


def _bench_linkage(rng, quick, tune, records):
    n = 1024 if quick else 8192
    ra = jnp.asarray(rng.standard_normal(n), jnp.float32)
    rb = jnp.asarray(rng.standard_normal(n), jnp.float32)
    mask = jnp.asarray((rng.random(n) > 0.2).astype(np.float32))

    def ref():
        return linkage_step_ref(ra, rb, 2.0, 3.0, mask)[0]

    return _bench_family(
        "linkage", f"n{n}", ref,
        lambda blk: link_ops.linkage_step(ra, rb, 2.0, 3.0, mask,
                                          block=blk["block"])[0],
        dict(n=n), tune, records)


def _bench_assign(rng, quick, tune, records):
    """The wave kernel vs the PR-7 per-arrival ``lax.map`` kernel vs jnp.

    ``default`` here is the REAL previous implementation
    (``assign_looped``), not just smaller tiles — the gap_shrink column
    measures the batched-matmul redesign plus the tile plan together.
    """
    b, d, k, t = (64, 32, 8, 8) if quick else (256, 32, 8, 16)
    v = jnp.asarray(rng.standard_normal((b, d, k)), jnp.float32)
    p = jnp.asarray(rng.standard_normal((t, d, d)), jnp.float32)
    dims = dict(b=b, d2=d * d)

    ref_out = np.asarray(jax.block_until_ready(assign_ref(v, p)[0]))
    ref_us = common.time_us(
        lambda: jax.block_until_ready(assign_ref(v, p)[0]))

    looped_us = common.time_us(
        lambda: jax.block_until_ready(assign_ops.assign_looped(v, p)[0]),
        n_iter=2)

    def wave(blocks):
        return assign_ops.assign(v, p, block_b=blocks["block_b"],
                                 block_d2=blocks["block_d2"])[0]

    if tune:
        tuning.autotune(
            "assign", lambda blk: jax.block_until_ready(wave(blk)),
            _grid_candidates("assign", **dims), **dims)
    blocks = tuning.get_blocks("assign", **dims)
    # Validate the fp32 path exactly; the timed path keeps the engine's
    # bf16 default, whose affinities drift but whose labels must agree.
    exact = np.asarray(jax.block_until_ready(
        assign_ops.assign(v, p, block_b=blocks["block_b"],
                          block_d2=blocks["block_d2"],
                          compute_dtype="fp32")[0]))
    labels = np.asarray(jax.block_until_ready(
        assign_ops.assign(v, p, block_b=blocks["block_b"],
                          block_d2=blocks["block_d2"])[1]))
    ref_labels = np.asarray(jax.block_until_ready(assign_ref(v, p)[1]))
    ok = (bool(np.allclose(exact, ref_out, rtol=1e-3, atol=1e-2))
          and bool((labels == ref_labels).all()))
    tuned_us = common.time_us(lambda: jax.block_until_ready(wave(blocks)))

    gap_default = looped_us / ref_us
    gap_tuned = tuned_us / ref_us
    shrink = gap_default / gap_tuned
    if not quick:
        assert shrink >= 5.0, (
            f"assign: wave kernel shrank the vs-jnp gap only "
            f"{shrink:.1f}x (< 5x)")
    records.append({
        "kernel": "assign", "shape": f"{b}x{d}x{k}x{t}", "dims": dims,
        "jnp_us": round(ref_us, 1),
        "default_us": round(looped_us, 1),
        "default_impl": "assign_looped (PR-7 per-arrival kernel)",
        "tuned_us": round(tuned_us, 1),
        "tuned_blocks": dict(blocks),
        "gap_default_vs_jnp": round(gap_default, 2),
        "gap_tuned_vs_jnp": round(gap_tuned, 2),
        "gap_shrink": round(shrink, 2),
        "validates": ok, "tuned": tune,
    })
    return common.row(
        f"kernel_assign_{b}x{d}x{k}x{t}", tuned_us,
        jnp_us=round(ref_us, 1), looped_us=round(looped_us, 1),
        gap_tuned_vs_jnp=round(gap_tuned, 2),
        gap_shrink_vs_looped=round(shrink, 2), validates=ok)


def _bench_engine_blockwise(rng, quick: bool) -> str:
    """Streaming R at a scale the dense path's Gram stack makes painful.

    Acceptance shape: N=2048 users, d=64, never materializing the
    (N, d, d) stack — peak Gram residency is block_users tiles.
    """
    n_users, n, d, k, block = ((256, 32, 64, 4, 64) if quick
                               else (2048, 32, 64, 4, 128))
    feats = jnp.asarray(rng.standard_normal((n_users, n, d)) * 0.3,
                        jnp.float32)
    cfg = sim.SimilarityConfig(top_k=k, block_users=block)
    eng = ProtocolEngine(cfg)
    result = {}

    def once():
        result["r"] = eng.similarity(feats).block_until_ready()

    us = common.time_us(once, n_iter=1, warmup=1)
    big_r = np.asarray(result["r"])
    return common.row(
        f"engine_blockwise_n{n_users}_d{d}", us,
        finite=bool(np.isfinite(big_r).all()),
        peak_gram_mb=round(block * d * d * 4 / 2**20, 2),
        dense_gram_mb=round(n_users * d * d * 4 / 2**20, 2))


def _bench_lps_round(rng, quick: bool) -> str:
    """Vectorized LPS round vs the seed per-client Python loop."""
    n_clients = 8 if quick else 32
    n_samples, m, steps, batch = 256, 64, 10, 32
    mcfg = mlp.PaperMLPConfig(m=m, hidden=32, n_classes=4)
    params = mlp.init(mcfg, jax.random.PRNGKey(0))
    loss_fn = mlp.loss_fn(mcfg)
    ccfg = fclient.ClientConfig(lr=0.05)
    xs = [rng.standard_normal((n_samples, m)).astype(np.float32)
          for _ in range(n_clients)]
    ys = [rng.integers(0, 4, n_samples).astype(np.int32)
          for _ in range(n_clients)]
    ns = [n_samples] * n_clients
    # One shared rng per path, same consumption order, so both paths train
    # on IDENTICAL batches and the speedup compares the same workload.
    loop_rng = np.random.default_rng(7)
    per_client = [fclient.make_batches(x, y, batch, steps, loop_rng)
                  for x, y in zip(xs, ys)]
    stacked = fclient.make_batch_stack(list(zip(xs, ys)), batch, steps,
                                       np.random.default_rng(7))

    def loop_round():
        client_params = []
        for b in per_client:
            new_p, _ = fclient.local_update(params, b, loss_fn, ccfg)
            client_params.append(new_p)
        return jax.block_until_ready(hier.lps_round(client_params, ns))

    def fused_round():
        new_p, _ = fclient.fused_lps_round(
            params, stacked, jnp.asarray(ns, jnp.float32), loss_fn, ccfg)
        return jax.block_until_ready(new_p)

    parity = all(
        np.allclose(np.asarray(a), np.asarray(b), rtol=1e-4, atol=1e-4)
        for a, b in zip(jax.tree.leaves(loop_round()),
                        jax.tree.leaves(fused_round())))
    loop_us = common.time_us(loop_round, n_iter=3)
    fused_us = common.time_us(fused_round, n_iter=3)
    return common.row(
        f"lps_round_{n_clients}clients", fused_us,
        loop_us=round(loop_us, 1),
        speedup_vs_loop=round(loop_us / fused_us, 2),
        matches_loop=parity)


def run(quick: bool = False, tune: bool = False,
        json_path: str | None = None) -> list[str]:
    rng = np.random.default_rng(0)
    records: list[dict] = []
    rows = [
        _bench_gram(rng, quick, tune, records),
        _bench_eigproject(rng, quick, tune, records),
        _bench_gram_project(rng, quick, tune, records),
        _bench_featurize_gram(rng, quick, tune, records),
        _bench_linkage(rng, quick, tune, records),
        _bench_assign(rng, quick, tune, records),
        _bench_engine_blockwise(rng, quick),
        _bench_lps_round(rng, quick),
    ]
    if json_path:
        common.record_result(json_path, {
            "quick": quick, "tuned_sweep": tune,
            "tune_cache_file": str(tuning.cache_path() or ""),
            "grid": records,
        })
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke: shrunken shapes, same code paths")
    ap.add_argument("--tune", action="store_true",
                    help="run the measured autotune sweep first (persists "
                         "when REPRO_TUNE_CACHE is set)")
    ap.add_argument("--json", default="benchmarks/results/bench_kernels.json",
                    help="where to record the tuned/default/jnp grid")
    args = ap.parse_args()
    for r in run(quick=args.quick, tune=args.tune, json_path=args.json):
        print(r, flush=True)
