"""Kernel + engine micro-benchmarks.

jnp reference wall time on CPU (the Pallas kernels target TPU and are
validated in interpret mode by the test suite; interpret-mode wall time is
not meaningful, so we time the reference path and report the kernels'
validation status + arithmetic intensity), plus two engine-level rows:

* ``engine_blockwise_*``: the streaming ``ProtocolEngine`` computing R for
  thousands of users on CPU with peak Gram memory O(block_users * d^2).
* ``lps_round_*``: the vectorized (vmap + scan, one jit) LPS round vs the
  seed's per-client Python loop — one cluster's worth of the MT-HFL hot
  path.  The WHOLE-trainer version of this comparison (cluster-stacked
  fused program vs the per-cluster loop, jnp and shard_map backends) lives
  in ``benchmarks/bench_trainer.py``.

Runs standalone too:  ``PYTHONPATH=src:. python benchmarks/bench_kernels.py
--quick`` (CI smoke: shrunken shapes, same code paths).
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro.core import similarity as sim
from repro.core.engine import ProtocolEngine
from repro.fed import client as fclient
from repro.fed import hierarchy as hier
from repro.kernels.eigproject import ops as proj_ops
from repro.kernels.eigproject.ref import project_norms_ref
from repro.kernels.gram import ops as gram_ops
from repro.kernels.gram.ref import gram_ref
from repro.kernels.gram_project import ops as gp_ops
from repro.kernels.gram_project.ref import gram_project_ref
from repro.models import mlp


def _bench_gram(rng, quick: bool) -> str:
    n, d = (512, 128) if quick else (2048, 256)
    x = jnp.asarray(rng.standard_normal((n, d)), jnp.float32)
    ref_us = common.time_us(lambda: gram_ref(x).block_until_ready())
    pall = gram_ops.gram_matrix(x, interpret=True)
    ok = bool(np.allclose(np.asarray(pall), np.asarray(gram_ref(x)),
                          rtol=1e-3, atol=1e-2))
    flops = 2 * n * d * d
    return common.row(
        f"kernel_gram_{n}x{d}", ref_us, ref_gflops=round(
            flops / ref_us / 1e3, 2), pallas_validates=ok,
        pallas_interpret=True,
        arithmetic_intensity=round(flops / (4 * (n * d + d * d)), 1))


def _bench_eigproject(rng, quick: bool) -> str:
    d, k = (128, 64) if quick else (256, 128)
    g = jnp.asarray(rng.standard_normal((d, d)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((d, k)), jnp.float32)
    ref_us = common.time_us(
        lambda: project_norms_ref(g, v).block_until_ready())
    pall = proj_ops.project_norms(g, v, interpret=True)
    ok = bool(np.allclose(np.asarray(pall),
                          np.asarray(project_norms_ref(g, v)),
                          rtol=1e-3, atol=1e-2))
    return common.row(
        f"kernel_eigproject_{d}x{k}", ref_us, pallas_validates=ok,
        pallas_interpret=True,
        fusion_saving_bytes=4 * d * k)  # the G@V intermediate never hits HBM


def _bench_gram_project(rng, quick: bool) -> str:
    n, d, k = (128, 128, 64) if quick else (256, 256, 256)
    x = jnp.asarray(rng.standard_normal((n, d)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((d, k)), jnp.float32)
    ref_us = common.time_us(
        lambda: gram_project_ref(x, v).block_until_ready())
    pall = gp_ops.gram_project(x, v, interpret=True)
    ok = bool(np.allclose(np.asarray(pall),
                          np.asarray(gram_project_ref(x, v)),
                          rtol=1e-3, atol=1e-2))
    return common.row(
        f"kernel_gram_project_{n}x{d}x{k}", ref_us, pallas_validates=ok,
        pallas_interpret=True,
        gram_bytes_never_materialized=4 * d * d)


def _bench_engine_blockwise(rng, quick: bool) -> str:
    """Streaming R at a scale the dense path's Gram stack makes painful.

    Acceptance shape: N=2048 users, d=64, never materializing the
    (N, d, d) stack — peak Gram residency is block_users tiles.
    """
    n_users, n, d, k, block = ((256, 32, 64, 4, 64) if quick
                               else (2048, 32, 64, 4, 128))
    feats = jnp.asarray(rng.standard_normal((n_users, n, d)) * 0.3,
                        jnp.float32)
    cfg = sim.SimilarityConfig(top_k=k, block_users=block)
    eng = ProtocolEngine(cfg)
    result = {}

    def once():
        result["r"] = eng.similarity(feats).block_until_ready()

    us = common.time_us(once, n_iter=1, warmup=1)
    big_r = np.asarray(result["r"])
    return common.row(
        f"engine_blockwise_n{n_users}_d{d}", us,
        finite=bool(np.isfinite(big_r).all()),
        peak_gram_mb=round(block * d * d * 4 / 2**20, 2),
        dense_gram_mb=round(n_users * d * d * 4 / 2**20, 2))


def _bench_lps_round(rng, quick: bool) -> str:
    """Vectorized LPS round vs the seed per-client Python loop."""
    n_clients = 8 if quick else 32
    n_samples, m, steps, batch = 256, 64, 10, 32
    mcfg = mlp.PaperMLPConfig(m=m, hidden=32, n_classes=4)
    params = mlp.init(mcfg, jax.random.PRNGKey(0))
    loss_fn = mlp.loss_fn(mcfg)
    ccfg = fclient.ClientConfig(lr=0.05)
    xs = [rng.standard_normal((n_samples, m)).astype(np.float32)
          for _ in range(n_clients)]
    ys = [rng.integers(0, 4, n_samples).astype(np.int32)
          for _ in range(n_clients)]
    ns = [n_samples] * n_clients
    # One shared rng per path, same consumption order, so both paths train
    # on IDENTICAL batches and the speedup compares the same workload.
    loop_rng = np.random.default_rng(7)
    per_client = [fclient.make_batches(x, y, batch, steps, loop_rng)
                  for x, y in zip(xs, ys)]
    stacked = fclient.make_batch_stack(list(zip(xs, ys)), batch, steps,
                                       np.random.default_rng(7))

    def loop_round():
        client_params = []
        for b in per_client:
            new_p, _ = fclient.local_update(params, b, loss_fn, ccfg)
            client_params.append(new_p)
        return jax.block_until_ready(hier.lps_round(client_params, ns))

    def fused_round():
        new_p, _ = fclient.fused_lps_round(
            params, stacked, jnp.asarray(ns, jnp.float32), loss_fn, ccfg)
        return jax.block_until_ready(new_p)

    parity = all(
        np.allclose(np.asarray(a), np.asarray(b), rtol=1e-4, atol=1e-4)
        for a, b in zip(jax.tree.leaves(loop_round()),
                        jax.tree.leaves(fused_round())))
    loop_us = common.time_us(loop_round, n_iter=3)
    fused_us = common.time_us(fused_round, n_iter=3)
    return common.row(
        f"lps_round_{n_clients}clients", fused_us,
        loop_us=round(loop_us, 1),
        speedup_vs_loop=round(loop_us / fused_us, 2),
        matches_loop=parity)


def run(quick: bool = False) -> list[str]:
    rng = np.random.default_rng(0)
    return [
        _bench_gram(rng, quick),
        _bench_eigproject(rng, quick),
        _bench_gram_project(rng, quick),
        _bench_engine_blockwise(rng, quick),
        _bench_lps_round(rng, quick),
    ]


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke: shrunken shapes, same code paths")
    args = ap.parse_args()
    for r in run(quick=args.quick):
        print(r, flush=True)
