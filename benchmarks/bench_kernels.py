"""Kernel micro-benchmarks: jnp reference wall time on CPU (the Pallas
kernels target TPU and are validated in interpret mode by the test suite;
interpret-mode wall time is not meaningful, so we time the reference path
and report the kernels' validation status + arithmetic intensity)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro.kernels.eigproject import ops as proj_ops
from repro.kernels.eigproject.ref import project_norms_ref
from repro.kernels.gram import ops as gram_ops
from repro.kernels.gram.ref import gram_ref


def run() -> list[str]:
    rng = np.random.default_rng(0)
    rows = []

    n, d = 2048, 256
    x = jnp.asarray(rng.standard_normal((n, d)), jnp.float32)
    ref_us = common.time_us(lambda: gram_ref(x).block_until_ready())
    pall = gram_ops.gram_matrix(x, interpret=True)
    ok = bool(np.allclose(np.asarray(pall), np.asarray(gram_ref(x)),
                          rtol=1e-3, atol=1e-2))
    flops = 2 * n * d * d
    rows.append(common.row(
        "kernel_gram_2048x256", ref_us, ref_gflops=round(
            flops / ref_us / 1e3, 2), pallas_validates=ok,
        arithmetic_intensity=round(flops / (4 * (n * d + d * d)), 1)))

    d, k = 256, 128
    g = jnp.asarray(rng.standard_normal((d, d)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((d, k)), jnp.float32)
    ref_us = common.time_us(
        lambda: project_norms_ref(g, v).block_until_ready())
    pall = proj_ops.project_norms(g, v, interpret=True)
    ok = bool(np.allclose(np.asarray(pall),
                          np.asarray(project_norms_ref(g, v)),
                          rtol=1e-3, atol=1e-2))
    rows.append(common.row(
        "kernel_eigproject_256x128", ref_us, pallas_validates=ok,
        fusion_saving_bytes=4 * d * k))  # the G@V intermediate never hits HBM
    return rows
