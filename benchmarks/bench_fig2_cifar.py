"""Paper Fig. 2: CIFAR-10 two-task MT-HFL — proposed clustering vs random.

The paper trains its 5x5-conv CNN per LPS sharing the conv layers through
the GPS and shows the proposed clustering beats random clustering in final
accuracy and variance.  We reproduce with the synthetic CIFAR-like data
(DESIGN.md §2) at reduced scale for CPU (--full for paper-scale rounds).
"""
from __future__ import annotations

import numpy as np

from benchmarks import common
from repro.data import partition as dpart
from repro.data import synthetic as syn
from repro.fed import client as fclient
from repro.fed import partition as fpart
from repro.fed import trainer as ftrainer
from repro.models import cnn


def run(seeds=(0, 1, 2), n_per_user=200, rounds=5) -> list[str]:
    users = dpart.paper_cifar_two_task(n_per_user=n_per_user, seed=0)

    def builder(classes):
        ccfg = cnn.PaperCNNConfig(n_classes=len(classes))
        return ftrainer.TaskModel(
            init=lambda k, c=ccfg: cnn.init(c, k),
            loss_fn=cnn.loss_fn(ccfg),
            accuracy=lambda p, x, y, c=ccfg: cnn.accuracy(c, p, x, y),
            is_common=fpart.prefix_predicate(cnn.COMMON_PREFIXES))

    cfg = ftrainer.MTHFLConfig(
        global_rounds=rounds, local_rounds=1, local_steps=12, batch_size=32,
        client=fclient.ClientConfig(lr=0.01, optimizer="momentum"))
    out = common.mthfl_compare(
        users, dpart.CIFAR_TASKS, builder,
        common.make_eval_spec(syn.CIFAR_LIKE, n=50), 2, seeds, cfg)
    return [common.row(
        "fig2_cifar_mthfl", 0.0,
        proposed_acc=round(float(out["proposed_mean"]), 4),
        proposed_std=round(float(out["proposed_std"]), 4),
        random_acc=round(float(out["random_mean"]), 4),
        random_std=round(float(out["random_std"]), 4),
        clustering_accuracy=out["clustering_accuracy"],
        beats_baseline=bool(out["proposed_mean"] > out["random_mean"]),
        lower_variance=bool(out["proposed_std"] <= out["random_std"] + 1e-9))]
