"""Telemetry overhead guard: instrumentation must be (near) free.

The obs contract (``repro/obs/core.py``) in numbers, on the two hot
paths the subsystem instruments most densely:

* **membership assign** — the batched directory lookup
  (``MembershipEngine.assign``), which carries a span, a latency
  histogram, a wave counter and an event per call;
* **serve decode loop** — ``ServeEngine.serve`` over a ragged request
  mix, which emits admission/slot/TTFT events per wave and per request.

Two bounds, both asserted and recorded in
``benchmarks/results/bench_obs.json``:

* **enabled <= 5%**: warm-path wall time with telemetry recording vs
  off.  Off/on calls strictly alternate (so thermal / frequency drift
  hits both sides equally) and each trial compares MEDIANS of per-call
  samples; the verdict takes the best trial — run-to-run variance on a
  shared CPU exceeds the bound itself, and the minimum over trials is
  the standard estimator for "cost is at most X".
* **disabled <= 0.5%**: the disabled path is a handful of constant-time
  no-op calls, so its overhead is computed DETERMINISTICALLY — the
  measured unit cost of the exact disabled call bundle one ``assign()``
  makes, divided by the warm op time — rather than differencing two
  large near-equal timings (which would drown a 0.5% bound in noise).

Retrace guard rides along: the jit cache-miss counter must not move
during the enabled warm phase, and ``ServeEngine.traces`` must be
identical enabled vs disabled (telemetry never changes what compiles).

Standalone: ``PYTHONPATH=src:. python benchmarks/bench_obs.py --quick``
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro import obs
from repro.configs.base import ArchConfig
from repro.core import oneshot
from repro.core.engine import ProtocolEngine
from repro.core.membership_engine import MembershipConfig, MembershipEngine
from repro.core.similarity import SimilarityConfig
from repro.data import synthetic as syn
from repro.launch.decode_loop import (ClusterHeads, Request, ServeConfig,
                                      ServeEngine)
from repro.models.registry import get_model

ENABLED_BOUND = 0.05
DISABLED_BOUND = 0.005


def _disabled_unit_cost_s(n: int = 200_000) -> float:
    """Measured cost of the exact disabled-mode call bundle one
    instrumented op makes: a clock read, a no-op span (enter / sync /
    exit) and the enabled() gate the post-op block hides behind."""
    assert not obs.enabled()
    t0 = obs.now()
    for _ in range(n):
        _ = obs.now()
        with obs.span("bench.noop", backend="jnp") as sp:
            sp.sync(None)
        if obs.enabled():
            raise AssertionError  # pragma: no cover
    return (obs.now() - t0) / n


def _one_assign(eng, lam_w, v_w) -> float:
    t0 = obs.now()
    out = eng.assign(lam_w, v_w)
    jax.block_until_ready(out.labels)
    return obs.now() - t0


def _median(xs: list) -> float:
    xs = sorted(xs)
    m = len(xs) // 2
    return xs[m] if len(xs) % 2 else 0.5 * (xs[m - 1] + xs[m])


def _bench_assign(quick: bool, records: list) -> list[str]:
    # Instrumentation cost is constant per wave (~30us of bookkeeping,
    # plus a fixed post-dispatch host penalty this machine charges ANY
    # work between blocked dispatches), so the bound is checked on a
    # bulk wave where the op itself is milliseconds: assign cost scales
    # with wave * T * k * d^2 and is independent of the table size N,
    # which only the full mode grows.
    n, wave = (256, 2048) if quick else (2048, 2048)
    d, samples, tasks, top_k = 32, 16, 8, 8
    feats, _ = syn.make_task_feature_mixture(n + wave, samples, d, tasks,
                                             seed=0)
    cfg = SimilarityConfig(top_k=top_k,
                           block_users=256 if n > 512 else 0)
    res = oneshot.one_shot_clustering(feats[:n], tasks, cfg=cfg)
    lam_w, v_w, _ = ProtocolEngine(
        SimilarityConfig(top_k=top_k)).signatures(feats[n:])
    eng = MembershipEngine.from_oneshot(res,
                                        MembershipConfig(backend="jnp"))

    # warm both modes up front so neither timed phase pays a compile
    obs.disable()
    jax.block_until_ready(eng.assign(lam_w, v_w).labels)
    with obs.scope(True):
        jax.block_until_ready(eng.assign(lam_w, v_w).labels)

    trials, n_pairs = (2, 30) if quick else (3, 60)
    enabled_overhead = float("inf")
    t_off = t_on = float("nan")
    retrace_delta = 0
    for _ in range(trials):
        offs, ons = [], []
        obs.enable()
        obs.reset()                            # bound record growth
        r0 = obs.counter_value("retrace_count")
        for _ in range(n_pairs):               # strict off/on alternation
            obs.disable()
            offs.append(_one_assign(eng, lam_w, v_w))
            obs.enable()
            ons.append(_one_assign(eng, lam_w, v_w))
        retrace_delta += int(obs.counter_value("retrace_count") - r0)
        obs.disable()
        trial = _median(ons) / _median(offs) - 1.0
        if trial < enabled_overhead:
            enabled_overhead = trial
            t_off, t_on = _median(offs), _median(ons)
    unit = _disabled_unit_cost_s(20_000 if quick else 200_000)
    disabled_overhead = unit / t_off

    assert retrace_delta == 0, (
        f"telemetry retraced the warm assign path ({retrace_delta} new "
        f"jit traces during the enabled timing phase)")
    assert enabled_overhead <= ENABLED_BOUND, (
        f"enabled telemetry overhead {enabled_overhead:.1%} > "
        f"{ENABLED_BOUND:.0%} on the assign path "
        f"({t_on * 1e6:.1f}us vs {t_off * 1e6:.1f}us)")
    assert disabled_overhead <= DISABLED_BOUND, (
        f"disabled telemetry overhead {disabled_overhead:.2%} > "
        f"{DISABLED_BOUND:.1%} ({unit * 1e9:.0f}ns bundle vs "
        f"{t_off * 1e6:.1f}us op)")

    records.append({
        "section": "assign", "N": n, "wave": wave, "backend": "jnp",
        "assign_disabled_us": round(t_off * 1e6, 2),
        "assign_enabled_us": round(t_on * 1e6, 2),
        "enabled_overhead_frac": round(enabled_overhead, 5),
        "disabled_call_bundle_ns": round(unit * 1e9, 1),
        "disabled_overhead_frac": round(disabled_overhead, 7),
        "retrace_delta_enabled": retrace_delta,
        "enabled_bound": ENABLED_BOUND,
        "disabled_bound": DISABLED_BOUND,
    })
    return [common.row(
        f"obs_overhead_assign_N{n}", t_off * 1e6,
        enabled_us=round(t_on * 1e6, 1),
        enabled_overhead=f"{enabled_overhead:+.2%}",
        disabled_overhead=f"{disabled_overhead:.4%}",
        retraces=retrace_delta)]


def _bench_serve(quick: bool, records: list) -> list[str]:
    # the decode loop's per-round host work means a too-small model makes
    # the event stream look expensive — the workload stays full-sized in
    # --quick, only the sampling shrinks
    d = 64
    cfg = ArchConfig(name="obs_bench", arch_type="dense",
                     n_layers=2, d_model=d, n_heads=4, n_kv_heads=2,
                     d_ff=2 * d, vocab=257, head_dim=d // 4,
                     block_pattern=("attn",), param_dtype="float32",
                     act_dtype="float32", scan_layers=False)
    m = get_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    heads = ClusterHeads.init(jax.random.PRNGKey(1), params["head"], 2)
    max_prompt, max_gen = 16, 8
    rng = np.random.default_rng(0)
    reqs = [Request(tokens=rng.integers(0, cfg.vocab, size=max_prompt)
                    .astype(np.int32), gen=max_gen, cluster=i % 2)
            for i in range(6)]
    scfg = ServeConfig(slots=4, wave=2, prefill_chunk=max_prompt // 2,
                       max_prompt=max_prompt, max_gen=max_gen,
                       max_len=max_prompt + max_gen)
    engine = ServeEngine(m, params, heads, scfg)
    obs.disable()
    engine.serve(reqs[:2])                     # warm the programs

    trials, n_pairs = (2, 6) if quick else (2, 10)
    enabled_overhead = float("inf")
    t_off = t_on = float("nan")
    traces_off = traces_on = None
    obs.enable()
    obs.reset()
    for _ in range(trials):
        offs, ons = [], []
        for _ in range(n_pairs):               # strict off/on alternation
            obs.disable()
            stats = engine.serve(reqs)
            offs.append(stats.wall_s)
            traces_off = dict(stats.traces)
            obs.enable()
            obs.clear_events()
            stats = engine.serve(reqs)
            ons.append(stats.wall_s)
            traces_on = dict(stats.traces)
        trial = _median(ons) / _median(offs) - 1.0
        if trial < enabled_overhead:
            enabled_overhead = trial
            t_off, t_on = _median(offs), _median(ons)
    obs.disable()

    assert traces_on == traces_off, (
        f"telemetry changed what the serving engine compiled: "
        f"{traces_off} vs {traces_on}")
    assert len(obs.events("request_done")) == len(reqs)
    assert enabled_overhead <= ENABLED_BOUND, (
        f"enabled telemetry overhead {enabled_overhead:.1%} > "
        f"{ENABLED_BOUND:.0%} on the decode loop "
        f"({t_on * 1e3:.1f}ms vs {t_off * 1e3:.1f}ms)")

    records.append({
        "section": "serve", "arch": cfg.name, "requests": len(reqs),
        "serve_disabled_ms": round(t_off * 1e3, 3),
        "serve_enabled_ms": round(t_on * 1e3, 3),
        "enabled_overhead_frac": round(enabled_overhead, 5),
        "traces_identical": True,
        "enabled_bound": ENABLED_BOUND,
    })
    return [common.row(
        "obs_overhead_serve_b6", t_off * 1e6,
        enabled_ms=round(t_on * 1e3, 2),
        enabled_overhead=f"{enabled_overhead:+.2%}",
        traces_identical=True)]


def run(quick: bool = False, json_path: str | None = None) -> list[str]:
    was_enabled = obs.enabled()
    records: list[dict] = []
    try:
        rows = _bench_assign(quick, records)
        rows += _bench_serve(quick, records)
    finally:
        obs.reset()
        (obs.enable if was_enabled else obs.disable)()
    if json_path:
        common.record_result(json_path, {
            "quick": quick, "backend": jax.default_backend(),
            "records": records,
        })
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke: shrunken shapes, same code paths")
    ap.add_argument("--json", default="benchmarks/results/bench_obs.json",
                    help="where to record the overhead verdicts")
    args = ap.parse_args()
    for r in run(quick=args.quick, json_path=args.json):
        print(r, flush=True)
