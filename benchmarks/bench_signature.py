"""Signature-stage benchmark: host-numpy ingest vs the SignatureEngine.

The seed pipeline STARTED on the host: per-user numpy ``feature_map``,
the materialized feature stack, and a full ``np.linalg.eigh`` (O(d^3))
per user for signatures that keep only ``top_k ~ 8`` eigenpairs.  The
``SignatureEngine`` runs the same raw -> (lam, V) stage device-resident:
jit-able Phi vmapped over users, optional row-chunk streaming with online
Gram accumulation (peak working set independent of n), and a batched
top-k subspace iteration (O(d^2 k iters)) instead of the eigh.

Modes timed (every point asserts top-k eigenvalue parity vs the host
reference):

  host           per-user numpy Phi + Gram + full eigh  (the seed path)
  jnp_dense      one-pass device featurize + Gram + subspace top-k
  jnp_stream     row-chunk streaming accumulation, same spectrum stage
  pallas_stream  fused kernels/featurize_gram chunks, bf16 compute

Acceptance (ISSUE 4): >= 5x end-to-end signature-stage speedup vs the
host-numpy path at N=512, d=256 on CPU, recorded in ``--json``, with
streaming peak memory independent of n (asserted analytically and
demonstrated by running the streaming mode at n and 2n).

Standalone: ``PYTHONPATH=src:. python benchmarks/bench_signature.py``
(CI smoke: ``--quick``, small grid, same code paths + assertions).
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from benchmarks import common
from repro.core.signature_engine import SignatureConfig, SignatureEngine
from repro.data import features as feat
from repro.data import synthetic as syn

TOP_K = 8
N_TASKS = 8


def host_ingest(raw: np.ndarray, fc: feat.FeatureConfig, top_k: int
                ) -> tuple[np.ndarray, np.ndarray]:
    """The seed path: numpy Phi per user, full eigh, keep top-k."""
    n_users = raw.shape[0]
    f0 = feat.feature_map(raw[0], fc)
    d = f0.shape[1]
    lams = np.empty((n_users, top_k), np.float32)
    vs = np.empty((n_users, d, top_k), np.float32)
    for i in range(n_users):
        f = feat.feature_map(raw[i], fc)
        g = f.T @ f / np.float32(f.shape[0])
        lam, v = np.linalg.eigh(g)
        lams[i] = lam[::-1][:top_k]
        vs[i] = v[:, ::-1][:, :top_k]
    return lams, vs


def stream_peak_bytes(n_users: int, chunk: int, m: int, d: int) -> int:
    """Streaming device working set: one raw chunk + the Gram stack + Phi
    params — NO term in n, which is the point."""
    return 4 * (n_users * chunk * m + n_users * d * d + m * d)


def dense_peak_bytes(n_users: int, n: int, m: int, d: int) -> int:
    """Dense working set: full raw stack + full feature stack + Grams."""
    return 4 * (n_users * n * m + n_users * n * d + n_users * d * d)


_LIVE_BYTES_CHILD = """
import sys
mode, n_users, n, m, d, chunk = sys.argv[1], *map(int, sys.argv[2:])
import jax
import repro.core.signature_engine as se
from repro.data import features as feat
from repro.data import synthetic as syn

raw, _ = syn.make_task_feature_mixture(n_users, n, m, 8, seed=0)
cfg = (se.SignatureConfig() if mode == "dense"
       else se.SignatureConfig(chunk_rows=chunk))
eng = se.SignatureEngine(feat.FeatureConfig(kind="random_projection",
                                            d=d), cfg)

peak = 0
orig = se._chunk_gram_accum
def spy(*args, **kwargs):
    # No blocking here: buffers held by the async dispatch queue are
    # still live arrays, so an unbounded queue shows up in the peak.
    global peak
    out = orig(*args, **kwargs)
    peak = max(peak, sum(x.nbytes for x in jax.live_arrays()))
    return out
se._chunk_gram_accum = spy
jax.block_until_ready(eng.grams(raw))
print(peak)
"""


def measured_peak_live_bytes(mode: str, n_users: int, n: int, m: int,
                             d: int, chunk: int) -> int:
    """Peak LIVE device-array bytes during ingest, sampled at every chunk
    step in a child process — the empirical check behind the analytic
    peak-bytes formulas.  Catches exactly the regressions that would
    re-couple peak memory to n: slicing the whole raw array onto the
    device, keeping past chunks alive, or letting the async dispatch
    queue hold every chunk at once (``jax.live_arrays`` sees any such
    buffer; malloc high-water noise does not pollute it)."""
    import subprocess
    import sys

    res = subprocess.run(
        [sys.executable, "-c", _LIVE_BYTES_CHILD, mode, str(n_users),
         str(n), str(m), str(d), str(chunk)],
        capture_output=True, text=True, timeout=600)
    assert res.returncode == 0, res.stderr[-1000:]
    return int(res.stdout.strip())


def _time_engine(eng: SignatureEngine, raw, top_k: int, n_iter: int = 2
                 ) -> tuple[float, np.ndarray]:
    """Min-of-repeats wall-clock (robust to background load spikes)."""
    lam, _, _ = eng.signatures(raw, top_k=top_k)          # compile
    jax.block_until_ready(lam)
    best = np.inf
    for _ in range(n_iter):
        t0 = time.perf_counter()
        lam, _, _ = eng.signatures(raw, top_k=top_k)
        jax.block_until_ready(lam)
        best = min(best, time.perf_counter() - t0)
    return best, np.asarray(lam)


SUBSPACE_ITERS = 8
TASK_RANK = 16


def bench_grid(n_users: int, n: int, m: int, d: int, chunk: int
               ) -> tuple[list[str], dict]:
    # Low-rank task subspaces (the paper's premise: users of one task
    # share a modest-rank second-moment structure) — the well-separated
    # regime where a handful of subspace iterations provably converge;
    # both eigenvalue parity AND the eigen-residual are asserted below,
    # so the iteration budget is checked, not assumed.
    raw, _ = syn.make_task_feature_mixture(n_users, n, m, N_TASKS, seed=0,
                                           rank=TASK_RANK)
    fc = feat.FeatureConfig(kind="random_projection", d=d)

    t_host = np.inf
    for _ in range(2):                  # min-of-2, same policy as device
        t0 = time.perf_counter()
        lam_h, _ = host_ingest(raw, fc, TOP_K)
        t_host = min(t_host, time.perf_counter() - t0)
    lam_scale = float(lam_h.max())

    modes = [
        ("jnp_dense", SignatureConfig(subspace_iters=SUBSPACE_ITERS,
                                      check=True), 1e-3),
        ("jnp_stream", SignatureConfig(chunk_rows=chunk,
                                       subspace_iters=SUBSPACE_ITERS,
                                       check=True), 1e-3),
        ("pallas_stream", SignatureConfig(backend="pallas",
                                          chunk_rows=chunk,
                                          subspace_iters=SUBSPACE_ITERS,
                                          compute_dtype="bf16"), 5e-2),
    ]
    rows, recs = [], []
    for name, cfg, tol in modes:
        eng = SignatureEngine(fc, cfg)
        dt, lam = _time_engine(eng, raw, TOP_K)
        relerr = float(np.abs(lam - lam_h).max() / lam_scale)
        assert relerr < tol, (
            f"{name} top-k eigenvalue parity broken at N={n_users} "
            f"d={d}: relerr={relerr:.2e} > {tol}")
        peak = (stream_peak_bytes(n_users, chunk, m, d) if cfg.chunk_rows
                else dense_peak_bytes(n_users, n, m, d))
        rec = {"mode": name, "seconds": round(dt, 4),
               "speedup_vs_host": round(t_host / dt, 2),
               "lam_relerr": relerr, "peak_bytes": peak}
        if cfg.backend == "pallas":
            rec["pallas_interpret"] = jax.default_backend() != "tpu"
        recs.append(rec)
        rows.append(common.row(
            f"signature_{name}_N{n_users}_d{d}", dt * 1e6,
            host_us=round(t_host * 1e6, 1),
            speedup_vs_host=rec["speedup_vs_host"], parity=True))

    # Streaming peak memory must not move with n.  The analytic formula
    # has no n term by construction; back it with a MEASURED check: peak
    # live device-array bytes during ingest at FOUR times n must match
    # the peak at n up to a couple of chunk buffers (the double-buffered
    # transfer window), while the dense one-pass peak scales with n.
    live = {f"stream_at_{mult}n_bytes":
            measured_peak_live_bytes("stream", n_users, mult * n, m, d,
                                     chunk)
            for mult in (1, 4)}
    live.update({f"dense_at_{mult}n_bytes":
                 measured_peak_live_bytes("dense", n_users, mult * n, m,
                                          d, chunk)
                 for mult in (1, 2)})
    chunk_bytes = 4 * n_users * chunk * m
    assert (live["stream_at_4n_bytes"]
            < live["stream_at_1n_bytes"] + 2 * chunk_bytes), (
        f"streaming ingest peak live bytes grew with n: {live}")
    record = {
        "N": n_users, "n": n, "m": m, "d": d, "top_k": TOP_K,
        "chunk_rows": chunk, "task_rank": TASK_RANK,
        "subspace_iters": SUBSPACE_ITERS,
        "host_s": round(t_host, 4),
        "modes": recs,
        "speedup_best": max(r["speedup_vs_host"] for r in recs),
        "stream_peak_bytes_analytic": stream_peak_bytes(n_users, chunk,
                                                        m, d),
        "dense_peak_bytes_analytic_at_n": dense_peak_bytes(n_users, n, m,
                                                           d),
        "dense_peak_bytes_analytic_at_2n": dense_peak_bytes(n_users,
                                                            2 * n, m, d),
        "measured_peak_live_bytes": live,
    }
    return rows, record


def run(quick: bool = False, json_path: str | None = None) -> list[str]:
    if quick:
        points = [(64, 64, 128, 64, 32)]
    else:
        # The ISSUE-4 acceptance point: N=512, d=256 on CPU.
        points = [(256, 128, 256, 128, 64), (512, 128, 512, 256, 64)]
    rows, records = [], []
    for (n_users, n, m, d, chunk) in points:
        r, rec = bench_grid(n_users, n, m, d, chunk)
        rows.extend(r)
        records.append(rec)
        jax.clear_caches()
    if not quick:
        final = records[-1]
        assert final["speedup_best"] >= 5.0, (
            f"acceptance: expected >= 5x signature-stage speedup at "
            f"N={final['N']}, d={final['d']}, got {final['speedup_best']}x")
    payload = {"quick": quick, "backend": jax.default_backend(),
               "grid": records}
    if json_path:
        common.record_result(json_path, payload)
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke: small grid, same code paths")
    ap.add_argument("--json",
                    default="benchmarks/results/bench_signature.json",
                    help="where to record the speedup grid")
    args = ap.parse_args()
    for r in run(quick=args.quick, json_path=args.json):
        print(r, flush=True)
