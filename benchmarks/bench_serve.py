"""Serving-path benchmark: static vs continuous batching + the
``recurrent_scan`` kernel family.

Three sections, all recorded into ``--json``
(``benchmarks/results/bench_serve.json``):

* **prefill dispatches** — COUNTED, not estimated: the old per-token
  ``greedy_decode`` path counts one jitted dispatch per prompt token
  (``DecodeStats.prefill_dispatches``); the ``ServeEngine`` wave prefill
  counts ONE host dispatch per admission wave, whose single ``lax.scan``
  covers ``max_prompt / prefill_chunk`` chunk steps
  (``ServeStats.prefill_dispatches`` / ``prefill_scan_steps``).
* **throughput** — the same ragged batch-8 request mix served two ways:
  the old static path (pad every prompt/gen to the max, per-token
  dispatch, useful tokens only counted) vs the continuous slot
  scheduler.  TTFT, slot utilization, and trace counts ride along; full
  mode asserts the >= 3x aggregate-tok/s acceptance bar.  A per-request
  sequential ``greedy_decode`` replay asserts the scheduler's outputs
  are token-identical.
* **recurrent_scan grid** — tuned vs default (pre-tuning 16/128 plan) vs
  jnp (``time_mix_chunked`` for wkv, ``associative_scan`` for the
  rglru recurrence), same harness as ``bench_kernels._bench_family``,
  plus fp32/bf16 parity vs the sequential fp32 oracle (bf16 <= 1e-3 at
  serving-scale activations).

Standalone: ``PYTHONPATH=src:. python benchmarks/bench_serve.py --quick``
(CI smoke: shrunken shapes, same code paths).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common
from benchmarks.bench_kernels import DEFAULT_BLOCKS, _bench_family
from repro.configs.base import ArchConfig
from repro.kernels import tuning
from repro.kernels.dispatch import resolve_interpret
from repro.kernels.recurrent_scan import ops as rs_ops
from repro.kernels.recurrent_scan.ref import linear_scan_ref, wkv_ref
from repro.launch.decode_loop import (ClusterHeads, Request, ServeConfig,
                                      ServeEngine, cluster_logits_fn,
                                      greedy_decode)
from repro.models import rwkv6
from repro.models.registry import get_model


# ---------------------------------------------------------------------------
# Serving comparison
# ---------------------------------------------------------------------------

def _bench_arch(quick: bool) -> ArchConfig:
    d = 64 if quick else 128
    return ArchConfig(name="serve_bench", arch_type="dense",
                      n_layers=2, d_model=d, n_heads=4, n_kv_heads=2,
                      d_ff=2 * d, vocab=257, head_dim=d // 4,
                      block_pattern=("attn",), param_dtype="float32",
                      act_dtype="float32", scan_layers=False)


def _ragged_mix(rng, n: int, vocab: int, max_prompt: int, max_gen: int,
                clusters: int) -> list[Request]:
    reqs = []
    for i in range(n):
        plen = int(rng.integers(max(4, max_prompt // 4), max_prompt + 1))
        gen = int(rng.integers(max(2, max_gen // 4), max_gen + 1))
        reqs.append(Request(
            tokens=rng.integers(0, vocab, size=plen).astype(np.int32),
            gen=gen, cluster=i % clusters))
    return reqs


def _bench_serving(rng, quick: bool, records: list) -> list[str]:
    cfg = _bench_arch(quick)
    m = get_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    n_clusters = 2 if quick else 4
    heads = ClusterHeads.init(jax.random.PRNGKey(1), params["head"],
                              n_clusters)
    max_prompt, max_gen = (16, 8) if quick else (64, 32)
    chunk = 8 if quick else 16
    reqs = _ragged_mix(rng, 8, cfg.vocab, max_prompt, max_gen, n_clusters)
    useful_tok = sum(r.gen for r in reqs)

    # -- old path: one static batch-8, everything padded to the max ------
    # NOTE: each call re-traces its step (the old path had no fixed-shape
    # program — ragged mixes changed (batch, len) and retraced); a warmup
    # call lets backend-level caches settle but the per-call trace stays,
    # exactly as it did in production.
    prompts = np.zeros((len(reqs), max_prompt), np.int32)
    for j, r in enumerate(reqs):
        prompts[j, max_prompt - len(r.tokens):] = r.tokens   # left pad
    lfn = cluster_logits_fn(heads, 0)
    greedy_decode(m, params, jnp.asarray(prompts), 2, logits_fn=lfn)
    t0 = time.perf_counter()
    static = greedy_decode(m, params, jnp.asarray(prompts), max_gen,
                           logits_fn=lfn)
    static_wall = time.perf_counter() - t0
    static_tok_s = useful_tok / static_wall

    # -- continuous path on the identical mix ----------------------------
    scfg = ServeConfig(slots=8, wave=4, prefill_chunk=chunk,
                       max_prompt=max_prompt, max_gen=max_gen,
                       max_len=max_prompt + max_gen)
    engine = ServeEngine(m, params, heads, scfg)
    engine.serve(reqs[:2])                     # warm the three programs
    stats = engine.serve(reqs)

    # token identity vs per-request sequential decode
    for i in range(2 if quick else 3):
        r = reqs[i]
        base = greedy_decode(m, params, jnp.asarray(r.tokens)[None, :],
                             r.gen,
                             logits_fn=cluster_logits_fn(heads, r.cluster))
        assert np.array_equal(np.asarray(base.tokens[0]),
                              stats.results[i].tokens), (
            f"slot scheduler diverged from sequential decode on request {i}")

    # counted dispatch accounting: old = one per prompt token; new = one
    # per admission wave (each a P/chunk-step scan)
    waves = stats.prefill_dispatches
    assert static.prefill_dispatches == max_prompt
    assert stats.prefill_scan_steps == max_prompt // chunk
    assert waves * stats.prefill_scan_steps <= static.prefill_dispatches, (
        "chunked prefill did not reduce dispatch count")
    assert all(v == 1 for v in stats.traces.values()), (
        f"serving programs retraced: {stats.traces}")

    speedup = stats.aggregate_tok_per_s / static_tok_s
    if not quick:
        assert speedup >= 3.0, (
            f"continuous batching {speedup:.2f}x vs static (< 3x) "
            f"({stats.aggregate_tok_per_s:.0f} vs {static_tok_s:.0f} tok/s)")
    records.append({
        "section": "serving", "arch": cfg.name,
        "requests": len(reqs), "useful_tokens": useful_tok,
        "max_prompt": max_prompt, "max_gen": max_gen,
        "prefill_chunk": chunk,
        "static_tok_per_s": round(static_tok_s, 1),
        "static_wall_s": round(static_wall, 3),
        "static_prefill_dispatches": static.prefill_dispatches,
        "static_ttft_s": round(static.ttft_s, 4),
        "continuous_tok_per_s": round(stats.aggregate_tok_per_s, 1),
        "continuous_wall_s": round(stats.wall_s, 3),
        "continuous_prefill_dispatches": waves,
        "prefill_scan_steps": stats.prefill_scan_steps,
        "continuous_decode_dispatches": stats.decode_dispatches,
        "mean_ttft_s": round(stats.mean_ttft_s, 4),
        "slot_utilization": round(stats.slot_utilization, 3),
        "traces": stats.traces,
        "speedup_vs_static": round(speedup, 2),
        "token_identical_vs_sequential": True,
    })
    return [common.row(
        "serve_continuous_vs_static_b8", stats.wall_s * 1e6,
        continuous_tok_s=round(stats.aggregate_tok_per_s, 1),
        static_tok_s=round(static_tok_s, 1),
        speedup=round(speedup, 2),
        prefill_dispatches=f"{waves}x{stats.prefill_scan_steps}steps"
                           f"_vs_{static.prefill_dispatches}",
        mean_ttft_ms=round(stats.mean_ttft_s * 1e3, 1),
        slot_util=round(stats.slot_utilization, 2))]


# ---------------------------------------------------------------------------
# recurrent_scan kernel grid + parity
# ---------------------------------------------------------------------------

def _wkv_inputs(rng, b, h, s, hd, scale=0.1):
    f = jnp.float32
    r = jnp.asarray(rng.standard_normal((b, s, h, hd)) * scale, f)
    k = jnp.asarray(rng.standard_normal((b, s, h, hd)) * scale, f)
    v = jnp.asarray(rng.standard_normal((b, s, h, hd)) * scale, f)
    logw = -jnp.asarray(np.exp(rng.standard_normal((b, s, h, hd)) - 1.0), f)
    u = jnp.asarray(rng.standard_normal((h, hd)) * scale, f)
    state = jnp.zeros((b, h, hd, hd), f)
    return r, k, v, logw, u, state


def _bench_wkv(rng, quick, tune, records):
    b, h, s, hd = (2, 2, 128, 64) if quick else (4, 4, 512, 64)
    r, k, v, logw, u, state = _wkv_inputs(rng, b, h, s, hd)
    ref = jax.jit(lambda: rwkv6.time_mix_chunked(r, k, v, logw, u, state,
                                                 chunk=64)[0])
    rows = [_bench_family(
        "recurrent_scan", f"wkv_{b}x{h}x{s}x{hd}", ref,
        lambda blk: rs_ops.wkv_chunked(r, k, v, logw, u, state,
                                       chunk=blk["chunk"])[0],
        dict(s=s, d=hd), tune, records)]

    # parity vs the sequential fp32 oracle, serving-scale activations
    want = np.asarray(wkv_ref(r, k, v, logw, u, state)[0])
    err = {}
    for cd in ("fp32", "bf16"):
        got = np.asarray(rs_ops.wkv_chunked(r, k, v, logw, u, state,
                                            compute_dtype=cd)[0],
                         np.float32)
        err[cd] = float(np.abs(got - want).max())
    assert err["fp32"] <= 1e-4, f"wkv fp32 parity {err['fp32']:.2e}"
    assert err["bf16"] <= 1e-3, f"wkv bf16 parity {err['bf16']:.2e}"
    records.append({"section": "parity", "kernel": "recurrent_scan/wkv",
                    "shape": f"{b}x{h}x{s}x{hd}",
                    "max_abs_err_fp32": err["fp32"],
                    "max_abs_err_bf16": err["bf16"]})
    rows.append(common.row(
        f"recurrent_scan_wkv_parity_{b}x{h}x{s}x{hd}", 0.0,
        err_fp32=f"{err['fp32']:.1e}", err_bf16=f"{err['bf16']:.1e}"))
    return rows


def _bench_linear_scan(rng, quick, tune, records):
    b, s, d = (4, 256, 256) if quick else (8, 1024, 512)
    f = jnp.float32
    log_a = -jnp.asarray(np.exp(rng.standard_normal((b, s, d)) - 2.0), f)
    x = jnp.asarray(rng.standard_normal((b, s, d)) * 0.1, f)
    h0 = jnp.asarray(rng.standard_normal((b, d)) * 0.1, f)

    @jax.jit
    def assoc_ref():
        x0 = x.at[:, 0, :].add(jnp.exp(log_a[:, 0, :]) * h0)

        def comb(c1, c2):
            a1, b1 = c1
            a2, b2 = c2
            return a1 + a2, jnp.exp(a2) * b1 + b2

        _, h = jax.lax.associative_scan(comb, (log_a, x0), axis=1)
        return h

    rows = [_bench_family(
        "recurrent_scan", f"rglru_{b}x{s}x{d}", assoc_ref,
        lambda blk: rs_ops.linear_scan(log_a, x, h0, chunk=blk["chunk"],
                                       block_d=blk["block_d"])[0],
        dict(s=s, d=d), tune, records)]

    want = np.asarray(linear_scan_ref(log_a, x, h0)[0])
    got = np.asarray(rs_ops.linear_scan(log_a, x, h0)[0])
    err = float(np.abs(got - want).max())
    assert err <= 1e-4, f"linear_scan fp32 parity {err:.2e}"
    records.append({"section": "parity",
                    "kernel": "recurrent_scan/linear_scan",
                    "shape": f"{b}x{s}x{d}", "max_abs_err_fp32": err})
    return rows


def run(quick: bool = False, tune: bool = False,
        json_path: str | None = None) -> list[str]:
    rng = np.random.default_rng(0)
    records: list[dict] = []
    rows = _bench_serving(rng, quick, records)
    rows += _bench_wkv(rng, quick, tune, records)
    rows += _bench_linear_scan(rng, quick, tune, records)
    if json_path:
        common.record_result(json_path, {
            "quick": quick, "tuned_sweep": tune,
            "pallas_interpret": bool(resolve_interpret(None)),
            "tune_cache_file": str(tuning.cache_path() or ""),
            "default_blocks": DEFAULT_BLOCKS["recurrent_scan"],
            "records": records,
        })
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke: shrunken shapes, same code paths")
    ap.add_argument("--tune", action="store_true",
                    help="run the measured autotune sweep first (persists "
                         "when REPRO_TUNE_CACHE is set)")
    ap.add_argument("--json", default="benchmarks/results/bench_serve.json",
                    help="where to record the serving + kernel grid")
    args = ap.parse_args()
    for r in run(quick=args.quick, tune=args.tune, json_path=args.json):
        print(r, flush=True)
