"""Paper Fig. 3: Fashion-MNIST three-task unbalanced MT-HFL.

Tasks: clothes (5 users, most data) / shoes (3) / bags (2, least data).
The paper's point: random clustering rarely groups the two bag users, so
Task-3 accuracy collapses with high variance; the proposed clustering
recovers it.  MLP per LPS, first layer shared through the GPS.
"""
from __future__ import annotations

import numpy as np

from benchmarks import common
from repro.data import partition as dpart
from repro.data import synthetic as syn
from repro.fed import client as fclient
from repro.fed import partition as fpart
from repro.fed import trainer as ftrainer
from repro.models import mlp


def run(seeds=(0, 1, 2), scale=0.2, rounds=5) -> list[str]:
    users = dpart.paper_fmnist_three_task(seed=0, scale=scale)

    def builder(classes):
        mcfg = mlp.PaperMLPConfig(m=784, n_classes=len(classes))
        return ftrainer.TaskModel(
            init=lambda k, c=mcfg: mlp.init(c, k),
            loss_fn=mlp.loss_fn(mcfg),
            accuracy=lambda p, x, y, c=mcfg: mlp.accuracy(c, p, x, y),
            is_common=fpart.prefix_predicate(mlp.COMMON_PREFIXES))

    cfg = ftrainer.MTHFLConfig(
        global_rounds=rounds, local_rounds=1, local_steps=10, batch_size=32,
        client=fclient.ClientConfig(lr=0.05, optimizer="momentum"))
    out = common.mthfl_compare(
        users, dpart.FMNIST_TASKS, builder,
        common.make_eval_spec(syn.FMNIST_LIKE, n=60), 3, seeds, cfg)
    rows = [common.row(
        "fig3_fmnist_mthfl", 0.0,
        proposed_acc=round(float(out["proposed_mean"]), 4),
        proposed_std=round(float(out["proposed_std"]), 4),
        random_acc=round(float(out["random_mean"]), 4),
        random_std=round(float(out["random_std"]), 4),
        clustering_accuracy=out["clustering_accuracy"],
        beats_baseline=bool(out["proposed_mean"] > out["random_mean"]))]
    for t in range(3):
        rows.append(common.row(
            f"fig3_fmnist_task{t + 1}", 0.0,
            proposed=round(float(out["proposed_per_task"][t]), 4),
            random=round(float(out["random_per_task"][t]), 4)))
    return rows
