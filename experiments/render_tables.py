"""Render EXPERIMENTS.md roofline tables from the dry-run artifacts.

Usage: python experiments/render_tables.py [tag]
  tag = "" for the paper-faithful baseline artifacts, "opt" for the
  optimized sweep.
"""
import json
import sys
from pathlib import Path

DIR = Path(__file__).parent / "dryrun"


def main():
    tag = sys.argv[1] if len(sys.argv) > 1 else ""
    suffix = f"__{tag}.json" if tag else ".json"
    rows = []
    for f in sorted(DIR.glob(f"*__pod{suffix}")):
        if not tag and "__" in f.name.replace("__pod.json", "").split(
                "__pod")[0].split("__", 2)[-1]:
            pass
        r = json.loads(f.read_text())
        if r.get("status") != "ok":
            rows.append((r["arch"], r["shape"], None, r.get("error", "")))
            continue
        roof = r["roofline"]
        rows.append((r["arch"], r["shape"], roof, r))
    print("| arch | shape | compute (s) | memory (s) | collective (s) |"
          " bottleneck | useful | HBM GB/dev | note |")
    print("|---|---|---|---|---|---|---|---|---|")
    for arch, shape, roof, r in rows:
        if roof is None:
            print(f"| {arch} | {shape} | - | - | - | FAIL | - | - | {r[:40]} |")
            continue
        hbm = r["memory"].get("total_hbm_bytes", 0) / 2 ** 30
        note = ("SWA variant" if r.get("variant") == "swa"
                and r["shape"] == "long_500k" else "")
        print(f"| {arch} | {shape} | {roof['compute_term_s']:.4f} "
              f"| {roof['memory_term_s']:.4f} "
              f"| {roof['collective_term_s']:.4f} | {roof['bottleneck']} "
              f"| {roof['useful_flops_ratio']:.3f} | {hbm:.1f} | {note} |")


if __name__ == "__main__":
    main()
