"""The paper's technique on the transformer zoo: federated LM users.

Users hold token streams from different DOMAINS (low-rank bigram sources).
Phi for token data is a fixed shared random embedding, mean-pooled over
windows (the LM analogue of the paper's fixed conv features, DESIGN.md §4).
The one-shot algorithm groups same-domain users; each LPS then fine-tunes
a reduced qwen3-family model with FedAvg, sharing the common representation
(embedding + first block) through the GPS.

    PYTHONPATH=src python examples/lm_federated.py --steps 30
"""
import argparse
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro import optim
from repro.configs.base import get_arch
from repro.core import clustering as clu
from repro.core import oneshot
from repro.core.similarity import SimilarityConfig
from repro.data import tokens as tok
from repro.fed.fedavg import fedavg
from repro.fed import partition as fpart
from repro.fed import hierarchy as hier
from repro.models.registry import get_model


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--users-per-domain", type=int, default=3)
    ap.add_argument("--domains", type=int, default=2)
    args = ap.parse_args()

    vocab = 256
    # --- 1. users + one-shot clustering on token features -------------
    specs = [tok.TokenTaskSpec(vocab=vocab, seed=d)
             for d in range(args.domains)]
    users, true = [], []
    for d, spec in enumerate(specs):
        for u in range(args.users_per_domain):
            stream = tok.sample_tokens(spec, 4096, seed=(d, u))
            users.append(stream)
            true.append(d)
    feats = [tok.token_features(s, d=64, window=8, vocab=vocab)
             for s in users]
    res = oneshot.one_shot_clustering(feats, n_clusters=args.domains,
                                      cfg=SimilarityConfig(top_k=8))
    acc = clu.clustering_accuracy(res.labels, true)
    print(f"one-shot clustering on token features: accuracy {acc:.0%} "
          f"(labels={res.labels.tolist()})")

    # --- 2. per-LPS FedAvg on a reduced qwen3, common layers via GPS ---
    cfg = dataclasses.replace(get_arch("qwen3_1_7b", reduced=True),
                              vocab=vocab)
    m = get_model(cfg)
    is_common = fpart.prefix_predicate(["embed"])  # shared representation
    lps_params = [m.init(jax.random.PRNGKey(t))
                  for t in range(args.domains)]
    opt = optim.adamw(3e-3)

    @jax.jit
    def client_step(params, batch):
        st = opt.init(params)
        loss, g = jax.value_and_grad(lambda p: m.loss_fn(p, batch))(params)
        upd, _ = opt.update(g, st, params)
        return optim.apply_updates(params, upd), loss

    B, S = 4, 64
    for rnd in range(args.steps // 10):
        for t in range(args.domains):
            members = [i for i, l in enumerate(res.labels) if l == t]
            new_params, losses = [], []
            for i in members:
                stream = users[i]
                off = (rnd * 17) % (len(stream) - B * S - 1)
                chunk = stream[off: off + B * S + 1]
                batch = {
                    "tokens": jnp.asarray(chunk[:-1].reshape(B, S)),
                    "labels": jnp.asarray(chunk[1:].reshape(B, S))}
                p = lps_params[t]
                for _ in range(10 // (args.domains)):
                    p, loss = client_step(p, batch)
                new_params.append(p)
                losses.append(float(loss))
            lps_params[t] = fedavg(new_params, [1] * len(new_params))
            print(f"round {rnd} LPS {t}: loss {np.mean(losses):.3f}")
        # GPS: average the common representation across LPSs
        lps_params = hier.gps_aggregate(lps_params,
                                        [1.0] * args.domains, is_common)
    print("done — per-LPS models trained; common layers GPS-averaged.")


if __name__ == "__main__":
    main()
