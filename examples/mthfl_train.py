"""End-to-end MT-HFL driver (paper Algorithm 1 + 2).

Clusters users with the one-shot algorithm, then runs hierarchical
federated training (per-LPS FedAvg; GPS aggregates the common layers) and
compares against the random-clustering baseline — the paper's Fig. 2/3
experiment as a single runnable script.

    PYTHONPATH=src python examples/mthfl_train.py --dataset fmnist \
        --rounds 8 --seeds 3
    PYTHONPATH=src python examples/mthfl_train.py --dataset cifar --rounds 4

``--fused`` / ``--backend`` select the trainer execution (see
``repro.fed.trainer``): the paper layouts have per-task head sizes, so
``--fused auto`` (default) runs the reference loop; ``--fused on`` forces
the cluster-stacked fused program and therefore requires homogeneous
heads (it raises otherwise, by design).
"""
import argparse
import sys
from pathlib import Path

import numpy as np

sys.path.insert(0, str(Path(__file__).resolve().parents[1]))  # benchmarks/
from benchmarks import common  # noqa: E402
from repro.data import partition as dpart
from repro.data import synthetic as syn
from repro.fed import client as fclient
from repro.fed import partition as fpart
from repro.fed import trainer as ftrainer
from repro.models import cnn, mlp


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", choices=["fmnist", "cifar"],
                    default="fmnist")
    ap.add_argument("--rounds", type=int, default=6)
    ap.add_argument("--seeds", type=int, default=3)
    ap.add_argument("--local-steps", type=int, default=10)
    ap.add_argument("--fused", choices=["auto", "on", "off"],
                    default="auto",
                    help="trainer path: cluster-stacked fused program "
                         "(on/auto) or the reference loop (off)")
    ap.add_argument("--backend", choices=ftrainer.TRAINER_BACKENDS,
                    default="jnp",
                    help="fused execution backend (shard_map shards the "
                         "cluster axis over local devices)")
    args = ap.parse_args()
    fused = {"auto": "auto", "on": True, "off": False}[args.fused]

    if args.dataset == "fmnist":
        users = dpart.paper_fmnist_three_task(seed=0, scale=0.25)
        tasks, spec, n_clusters = dpart.FMNIST_TASKS, syn.FMNIST_LIKE, 3

        def builder(classes):
            c = mlp.PaperMLPConfig(m=784, n_classes=len(classes))
            return ftrainer.TaskModel(
                init=lambda k, cc=c: mlp.init(cc, k),
                loss_fn=mlp.loss_fn(c),
                accuracy=lambda p, x, y, cc=c: mlp.accuracy(cc, p, x, y),
                is_common=fpart.prefix_predicate(mlp.COMMON_PREFIXES))
    else:
        users = dpart.paper_cifar_two_task(n_per_user=300, seed=0)
        tasks, spec, n_clusters = dpart.CIFAR_TASKS, syn.CIFAR_LIKE, 2

        def builder(classes):
            c = cnn.PaperCNNConfig(n_classes=len(classes))
            return ftrainer.TaskModel(
                init=lambda k, cc=c: cnn.init(cc, k),
                loss_fn=cnn.loss_fn(c),
                accuracy=lambda p, x, y, cc=c: cnn.accuracy(cc, p, x, y),
                is_common=fpart.prefix_predicate(cnn.COMMON_PREFIXES))

    cfg = ftrainer.MTHFLConfig(
        global_rounds=args.rounds, local_rounds=1,
        local_steps=args.local_steps, batch_size=32,
        client=fclient.ClientConfig(lr=0.05, optimizer="momentum"),
        backend=args.backend)
    out = common.mthfl_compare(users, tasks, builder,
                               common.make_eval_spec(spec, n=60),
                               n_clusters, tuple(range(args.seeds)), cfg,
                               fused=fused)

    print(f"\n=== MT-HFL on {args.dataset} "
          f"({args.rounds} global rounds, {args.seeds} seeds) ===")
    print(f"one-shot clustering accuracy : "
          f"{out['clustering_accuracy']:.0%}")
    print(f"proposed : acc={out['proposed_mean']:.4f} "
          f"+- {out['proposed_std']:.4f}  per-task="
          f"{np.round(out['proposed_per_task'], 3)}")
    print(f"random   : acc={out['random_mean']:.4f} "
          f"+- {out['random_std']:.4f}  per-task="
          f"{np.round(out['random_per_task'], 3)}")
    verdict = "BEATS" if out["proposed_mean"] > out["random_mean"] else \
        "does NOT beat"
    print(f"--> proposed clustering {verdict} the random baseline "
          f"(paper Fig. {'3' if args.dataset == 'fmnist' else '2'})")


if __name__ == "__main__":
    main()
