"""Quickstart: one-shot data-similarity clustering in ~40 lines.

Builds the paper's CIFAR-10 two-task federation (synthetic stand-in),
runs Algorithm 2 (Gram spectra -> eigenvector exchange -> relevance ->
HAC), and prints the similarity matrix, the recovered clusters, and the
communication ledger.

    PYTHONPATH=src python examples/quickstart.py
    PYTHONPATH=src python examples/quickstart.py --cluster-backend jnp
    PYTHONPATH=src python examples/quickstart.py --host-ingest
    PYTHONPATH=src python examples/quickstart.py --arrivals 4
"""
import argparse

import numpy as np

from repro.core import clustering as clu
from repro.core import oneshot
from repro.core.cluster_engine import ClusterConfig
from repro.core.membership_engine import MembershipConfig, MembershipEngine
from repro.core.signature_engine import SignatureConfig, SignatureEngine
from repro.core.similarity import SimilarityConfig
from repro.data import features as feat
from repro.data import partition as dpart


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--cluster-backend", default="numpy",
                    choices=["numpy", "jnp", "pallas"],
                    help="GPS decision layer: host reference HAC or the "
                         "device NN-chain ClusterEngine")
    ap.add_argument("--host-ingest", action="store_true",
                    help="featurize per user with host numpy (the pre-PR-4 "
                         "path) instead of the device SignatureEngine")
    ap.add_argument("--arrivals", type=int, default=0, metavar="B",
                    help="serve B streaming newcomers AFTER the one-shot "
                         "round via the MembershipEngine cluster directory "
                         "(no protocol re-run)")
    args = ap.parse_args()

    # 10 users, 2 tasks (vehicles / animals), 10% minority labels.
    users = dpart.paper_cifar_two_task(n_per_user=400, seed=0)
    print(f"{len(users)} users; true tasks:",
          [u.task_id for u in users])

    # Phi: fixed shared random projection (ResNet18 surrogate, DESIGN.md §2)
    fc = feat.FeatureConfig(kind="random_projection", d=128)

    if args.host_ingest:
        # Host path: numpy Phi per user, protocol sees feature matrices.
        feats = [feat.feature_map(u.x, fc) for u in users]
        res = oneshot.one_shot_clustering(
            feats, n_clusters=2, cfg=SimilarityConfig(top_k=8),
            cluster_cfg=ClusterConfig(backend=args.cluster_backend),
            model_params=62_006)  # paper CNN size, for the comm comparison
    else:
        # Raw-data entry point: hand raw shards + the FeatureConfig; the
        # SignatureEngine featurizes on-device, streaming 128-row chunks
        # and extracting top-k signatures by subspace iteration (no eigh).
        res = oneshot.one_shot_clustering(
            [u.x for u in users], n_clusters=2,
            cfg=SimilarityConfig(top_k=8),
            cluster_cfg=ClusterConfig(backend=args.cluster_backend),
            feature_cfg=fc,
            signature_cfg=SignatureConfig(chunk_rows=128),
            model_params=62_006)

    np.set_printoptions(precision=2, suppress=True)
    print("\nSimilarity matrix R (paper Table I analogue):")
    print(np.asarray(res.similarity))
    labels = np.asarray(res.labels)
    print(f"\nClusters ({args.cluster_backend} backend):", labels)
    acc = clu.clustering_accuracy(labels, [u.task_id for u in users])
    print(f"Clustering accuracy vs oracle: {acc:.0%}")
    print("\nCommunication ledger (one-shot, before any training):")
    for k, v in res.ledger.summary().items():
        print(f"  {k}: {v}")

    if args.arrivals:
        # Streaming arrivals: newcomers who missed the one-shot round.
        # Their cluster identity comes from the directory the GPS kept —
        # one (k x d) signature upload, one label download, no re-run.
        newcomers = dpart.paper_cifar_two_task(
            n_per_user=400, seed=1,
            users_per_task=(args.arrivals - args.arrivals // 2,
                            args.arrivals // 2))
        sig_engine = SignatureEngine(fc, SignatureConfig(chunk_rows=128))
        lam_w, v_w, _ = sig_engine.signatures(
            [u.x for u in newcomers], top_k=8)
        engine = MembershipEngine.from_oneshot(res, MembershipConfig(
            backend="numpy" if args.cluster_backend == "numpy" else "jnp"))
        out = engine.assign(lam_w, v_w)
        engine.admit(lam_w, v_w, out.labels)
        print(f"\nStreaming arrivals ({args.arrivals} newcomers, no "
              f"protocol re-run):")
        for u, l, m in zip(newcomers, np.asarray(out.labels),
                           np.asarray(out.margin)):
            print(f"  newcomer task {u.task_id} -> cluster {l} "
                  f"(margin {m:.3f})")
        led = res.ledger
        print(f"arrival upload {led.assign_upload} B vs protocol "
              f"per-user upload {led.per_user_upload} B; download "
              f"{led.assign_download} B (one label)")


if __name__ == "__main__":
    main()
