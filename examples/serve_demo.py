"""Batched serving demo: prefill + KV-cache decode on a reduced arch.

Shows the serve path the dry-run lowers at production scale (decode_32k /
long_500k): teacher-forced prefill fills the cache, then serve_step
generates tokens one at a time (greedy).

    PYTHONPATH=src python examples/serve_demo.py --arch granite_8b \
        --batch 4 --gen 16
    PYTHONPATH=src python examples/serve_demo.py --arch rwkv6_1_6b
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs.base import get_arch
from repro.models.registry import get_model


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite_8b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    args = ap.parse_args()

    cfg = get_arch(args.arch, reduced=True)
    m = get_model(cfg)
    if m.is_encdec:
        raise SystemExit("use a decoder-only arch for this demo")
    params = m.init(jax.random.PRNGKey(0))
    prompt = jax.random.randint(jax.random.PRNGKey(1),
                                (args.batch, args.prompt_len), 0, cfg.vocab)

    # Prefill: feed the prompt token-by-token through the cache (a blocked
    # prefill kernel would batch this on TPU; the contract is identical).
    state = m.init_decode_state(args.batch, args.prompt_len + args.gen)
    step = jax.jit(m.decode_step)
    t0 = time.time()
    logits = None
    for t in range(args.prompt_len):
        logits, state = step(params, prompt[:, t:t + 1], state)
    print(f"prefill {args.prompt_len} tokens: {time.time() - t0:.2f}s")

    # Greedy decode.
    tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
    out = [tok]
    t0 = time.time()
    for _ in range(args.gen - 1):
        logits, state = step(params, tok, state)
        tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
        out.append(tok)
    dt = time.time() - t0
    gen = jnp.concatenate(out, axis=1)
    print(f"generated {args.gen} tokens x {args.batch} seqs "
          f"in {dt:.2f}s ({args.batch * args.gen / max(dt, 1e-9):.1f} tok/s)")
    print("sample:", gen[0].tolist())


if __name__ == "__main__":
    main()
