"""Batched serving demo: prefill + KV-cache decode on a reduced arch.

Shows the serve path the dry-run lowers at production scale (decode_32k /
long_500k): teacher-forced prefill fills the cache, then serve_step
generates tokens one at a time (greedy).  The loop itself is the shared
``repro.launch.decode_loop.greedy_decode`` — the same one
``launch/serve.py`` drives.

    PYTHONPATH=src python examples/serve_demo.py --arch granite_8b \
        --batch 4 --gen 16
    PYTHONPATH=src python examples/serve_demo.py --arch rwkv6_1_6b
"""
import argparse

import jax

from repro.configs.base import get_arch
from repro.launch.decode_loop import greedy_decode
from repro.models.registry import get_model


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite_8b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    args = ap.parse_args()

    cfg = get_arch(args.arch, reduced=True)
    m = get_model(cfg)
    if m.is_encdec:
        raise SystemExit("use a decoder-only arch for this demo")
    params = m.init(jax.random.PRNGKey(0))
    prompt = jax.random.randint(jax.random.PRNGKey(1),
                                (args.batch, args.prompt_len), 0, cfg.vocab)

    stats = greedy_decode(m, params, prompt, args.gen)
    print(f"prefill {args.prompt_len} tokens: {stats.prefill_s:.2f}s")
    print(f"generated {args.gen} tokens x {args.batch} seqs "
          f"in {stats.decode_s:.2f}s ({stats.tok_per_s:.1f} tok/s)")
    print("sample:", stats.tokens[0].tolist())


if __name__ == "__main__":
    main()
